"""Distribution context shared by model code.

Model forward functions are written once and work in two modes:

* local (no mesh): smoke tests / single-device examples — plain jnp, MoE uses
  the local dispatch path.
* distributed (mesh set): the launcher installs a mesh + logical axis
  assignment here; MoE switches to the expert-parallel ``shard_map`` path and
  activation sharding constraints become active.

This avoids threading mesh handles through every call site while keeping
``jax.jit`` tracing pure (the context is read at trace time).
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


@dataclass
class DistContext:
    mesh: Optional[Mesh] = None
    # logical axis name -> mesh axis name(s)
    batch_axes: Optional[Sequence[str]] = ("data",)   # batch dim of activations
    model_axes: Optional[Sequence[str]] = ("model",)  # tensor-parallel dim
    # None batch_axes => batch replicated (e.g. long_500k with B=1)

    @property
    def active(self) -> bool:
        return self.mesh is not None

    def axis_size(self, axes) -> int:
        if not self.active or axes is None:
            return 1
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n


_CTX = DistContext()


def get_ctx() -> DistContext:
    return _CTX


def set_mesh(mesh: Optional[Mesh], batch_axes=("data",), model_axes=("model",)) -> None:
    global _CTX
    _CTX = DistContext(mesh=mesh, batch_axes=tuple(batch_axes) if batch_axes else None,
                       model_axes=tuple(model_axes) if model_axes else None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], batch_axes=("data",), model_axes=("model",)):
    global _CTX
    prev = _CTX
    set_mesh(mesh, batch_axes, model_axes)
    try:
        yield _CTX
    finally:
        _CTX = prev


def constrain(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint that is a no-op without a mesh."""
    ctx = get_ctx()
    if not ctx.active:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(ctx.mesh, P(*spec)))


def batch_spec_entry():
    """PartitionSpec entry for the activation batch dimension."""
    ctx = get_ctx()
    if not ctx.active or ctx.batch_axes is None:
        return None
    return tuple(ctx.batch_axes) if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]


def model_spec_entry():
    ctx = get_ctx()
    if not ctx.active or ctx.model_axes is None:
        return None
    return tuple(ctx.model_axes) if len(ctx.model_axes) > 1 else ctx.model_axes[0]


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` with replication checking disabled.

    jax >= 0.6 exposes ``jax.shard_map`` (keyword ``check_vma``); older
    releases only have ``jax.experimental.shard_map.shard_map`` (keyword
    ``check_rep``). All call sites in this repo disable the check because
    outputs mix per-shard and replicated values.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
