"""Attention: GQA with RoPE, optional qk-norm and sliding window.

Three execution paths, all numerically equivalent:

* ``attend_full``    — direct masked softmax; used when S is small.
* ``attend_chunked`` — flash-style two-level blocked attention (scan over
  query blocks; inner scan over KV blocks with running (m, l, acc)). Keeps
  the HLO's peak temp memory at O(Bq*Bk) instead of O(S^2); used for the
  long prefill/train shapes. This is the TPU-native analogue of an
  IO-aware attention kernel at the XLA level.
* ``attend_decode``  — one query token against a KV cache with a length mask.

KV caches are per-layer ``(B, S_max, kv_heads, head_dim)``; sliding-window
archs keep a ring buffer of ``window`` entries (so a 500k-token context costs
O(window) memory, which is what makes ``long_500k`` lowerable for dense
archs).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import dist
from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


class AttnParams(NamedTuple):
    pass  # attention params live in plain dicts; kept for type clarity


def init_attention(key, cfg: ModelConfig, stacked: int = 0, cross: bool = False):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)

    def mk(k, i, o):
        if stacked:
            import repro.models.layers as L
            return L.stacked_dense_init(k, stacked, i, o, dtype)
        return dense_init(k, i, o, dtype)

    p = {
        "w_q": mk(ks[0], d, nh * hd),
        "w_k": mk(ks[1], d, nkv * hd),
        "w_v": mk(ks[2], d, nkv * hd),
        "w_o": mk(ks[3], nh * hd, d),
    }
    if cfg.qk_norm:
        shape = (stacked, hd) if stacked else (hd,)
        p["q_norm"] = jnp.ones(shape, dtype)
        p["k_norm"] = jnp.ones(shape, dtype)
    return p


def _project_qkv(params, x, x_kv, cfg: ModelConfig, positions, kv_positions=None,
                 rope: bool = True):
    """Project to q/k/v, apply qk-norm + RoPE. Returns (q, k, v) with shapes
    (B, Sq, nh, hd), (B, Skv, nkv, hd), (B, Skv, nkv, hd)."""
    hd = cfg.resolved_head_dim
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    B, Sq = x.shape[0], x.shape[1]
    Skv = x_kv.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, params["w_q"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dh->bsh", x_kv, params["w_k"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dh->bsh", x_kv, params["w_v"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = q.reshape(B, Sq, nh, hd)
    k = k.reshape(B, Skv, nkv, hd)
    v = v.reshape(B, Skv, nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.rmsnorm_eps)
        k = rms_norm(k, params["k_norm"], cfg.rmsnorm_eps)
    if rope and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        kp = kv_positions if kv_positions is not None else positions
        k = apply_rope(k, kp, cfg.rope_theta)
    return q, k, v


def _expand_gqa(q, nkv: int):
    """(B, S, nh, hd) -> (B, S, nkv, group, hd)."""
    B, S, nh, hd = q.shape
    return q.reshape(B, S, nkv, nh // nkv, hd)


def _attend_scores_softmax(q, k, v, mask, scale):
    """q: (B,Sq,nkv,g,hd)  k/v: (B,Skv,nkv,hd)  mask: (B|1,1,Sq,Skv) bool."""
    scores = jnp.einsum("bqngh,bknh->bngqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[:, :, None, :, :] if mask.ndim == 4 else mask,
                       scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngqk,bknh->bqngh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32).astype(v.dtype)
    return out


def attend_full(q, k, v, *, causal: bool, window: int, q_offset=0,
                kv_len: Optional[jnp.ndarray] = None):
    """Direct attention. q: (B,Sq,nkv,g,hd); k,v: (B,Skv,nkv,hd)."""
    B, Sq = q.shape[0], q.shape[1]
    Skv = k.shape[1]
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    qi = jnp.arange(Sq)[:, None] + q_offset
    kj = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kj <= qi
    if window:
        mask &= kj > qi - window
    mask = jnp.broadcast_to(mask[None, :, :], (B, Sq, Skv))
    if kv_len is not None:
        mask &= kj[None] < kv_len[:, None, None]
    mask = mask[:, None, :, :]  # (B, 1, Sq, Skv)
    return _attend_scores_softmax(q, k, v, mask, scale)


def attend_chunked(q, k, v, *, causal: bool, window: int, chunk_q: int = 512,
                   chunk_k: int = 512):
    """Flash-style blocked attention with running max/sum.

    Shapes as in attend_full. Non-multiple sequence lengths are padded at
    the end (causal masking makes the pad keys invisible to real queries;
    pad query rows are sliced off).
    """
    Sq_real, Skv_real = q.shape[1], k.shape[1]
    pq = (-Sq_real) % chunk_q
    pk = (-Skv_real) % chunk_k
    if pq or pk:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        out = attend_chunked(q, k, v, causal=causal, window=window,
                             chunk_q=chunk_q, chunk_k=chunk_k)
        return out[:, :Sq_real]
    B, Sq, nkv, g, hd = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // chunk_q, Skv // chunk_k
    scale = 1.0 / math.sqrt(hd)

    qc = q.reshape(B, nq, chunk_q, nkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, chunk_k, nkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, chunk_k, nkv, hd).transpose(1, 0, 2, 3, 4)

    def q_block(qi, q_blk):
        # running accumulators over kv blocks
        m0 = jnp.full((B, nkv, g, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, nkv, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((B, nkv, g, chunk_q, hd), jnp.float32)

        def kv_block(carry, inp):
            m, l, acc = carry
            kj, k_blk, v_blk = inp
            s = jnp.einsum("bqngh,bknh->bngqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            # block-level mask
            qpos = qi * chunk_q + jnp.arange(chunk_q)[:, None]
            kpos = kj * chunk_k + jnp.arange(chunk_k)[None, :]
            msk = jnp.ones((chunk_q, chunk_k), bool)
            if causal:
                msk &= kpos <= qpos
            if window:
                msk &= kpos > qpos - window
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bngqk,bknh->bngqh", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        ks_idx = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), (ks_idx, kc, vc))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)  # (B, chunk_q, nkv, g, hd)

    outs = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qc))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, nkv, g, hd)
    return out.astype(v.dtype)


def attend_chunk_cached(q, cache_k, cache_v, offsets):
    """Continue-prefill attention: C query tokens per row at per-row offsets
    against the (already written) KV cache.

    q: (B, C, nkv, g, hd); cache_k/v: (B, Sc, nkv, hd); offsets: (B,) valid
    cache entries BEFORE this chunk. Query i of row b sits at absolute
    position offsets[b]+i and attends to cache slots <= offsets[b]+i (its
    own chunk prefix included — the chunk's K/V are written before this
    runs, mirroring the decode path). No ring-buffer support: the engine
    gates chunked prefill to full-causal archs (DESIGN.md §8).
    """
    B, C = q.shape[0], q.shape[1]
    Sc = cache_k.shape[1]
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    qi = offsets[:, None, None] + jnp.arange(C)[None, :, None]
    kj = jnp.arange(Sc)[None, None, :]
    mask = (kj <= qi)[:, None, :, :]           # (B, 1, C, Sc)
    return _attend_scores_softmax(q, cache_k, cache_v, mask, scale)


# ---------------------------------------------------------------------------
# Paged KV primitives (vLLM-style block pool — DESIGN.md §9)
#
# The pool is (NB, bs, kv, hd) per layer; a per-row block table (B, MB) of
# pool indices (-1 = unallocated) maps logical position p of row b to
# physical slot table[b, p // bs] * bs + p % bs. Attention never runs over
# the pool directly: `gather_block_view` materializes a contiguous
# (B, MB*bs, kv, hd) view and the existing `attend_decode` /
# `attend_chunk_cached` length masks do the rest — pages change where K/V
# live, never their values, so the paged path is bit-identical to the
# contiguous cache whenever the view width matches the contiguous S_c.
# ---------------------------------------------------------------------------


def gather_block_view(pool_layer, block_table, block_size: int):
    """Materialize one layer's contiguous view of the block pool.

    pool_layer: (NB, bs, kv, hd); block_table: (B, MB) int32 (-1 = free).
    Returns (B, MB*bs, kv, hd). Unallocated table entries read block 0 —
    those positions are always >= the row's length and masked in attention.
    """
    B, MB = block_table.shape
    g = pool_layer[jnp.maximum(block_table, 0)]     # (B, MB, bs, kv, hd)
    return g.reshape(B, MB * block_size, *pool_layer.shape[2:])


def flat_block_indices(block_table, lens, valid, block_size: int,
                       num_blocks: int):
    """Physical destinations for a (B, C) slab write starting at ``lens``.

    valid: (B, C) bool — which of the C candidate tokens per row to write.
    Returns (B, C) int32 indices into the flattened (NB*bs) pool; invalid
    positions (masked, past the table, or on an unallocated block) map to
    NB*bs, i.e. out of bounds, so a scatter with mode="drop" skips them.
    """
    B, C = valid.shape
    MB = block_table.shape[1]
    pos = lens[:, None] + jnp.arange(C, dtype=lens.dtype)[None, :]
    blk = pos // block_size
    ok = valid & (blk < MB)
    pool_idx = jnp.take_along_axis(block_table, jnp.clip(blk, 0, MB - 1),
                                   axis=1)
    ok &= pool_idx >= 0
    flat = pool_idx * block_size + pos % block_size
    return jnp.where(ok, flat, num_blocks * block_size).astype(jnp.int32)


def scatter_block_kv(pool, new, flat):
    """Scatter new K/V entries into the (flattened) block pool.

    pool: (NB, bs, kv, hd) or (L, NB, bs, kv, hd); new: (B, C, kv, hd) or
    (L, B, C, kv, hd); flat: (B, C) from :func:`flat_block_indices`
    (out-of-bounds entries are dropped). Valid destinations are unique —
    rows own disjoint blocks and positions within a row are distinct — so
    the scatter is order-independent.
    """
    idx = flat.reshape(-1)
    if pool.ndim == 5:
        L, NB, bs = pool.shape[:3]
        pf = pool.reshape(L, NB * bs, *pool.shape[3:])
        pf = pf.at[:, idx].set(new.reshape(L, -1, *new.shape[3:]),
                               mode="drop")
        return pf.reshape(pool.shape)
    NB, bs = pool.shape[:2]
    pf = pool.reshape(NB * bs, *pool.shape[2:])
    pf = pf.at[idx].set(new.reshape(-1, *new.shape[2:]), mode="drop")
    return pf.reshape(pool.shape)


def attend_paged(q, k_pool_layer, v_pool_layer, block_table, kv_len,
                 block_size: int):
    """Decode attention straight off one layer's block pool: gather the
    contiguous block view, then run the standard length-masked decode
    attention over it. q: (B, 1, nkv, g, hd); kv_len: (B,) valid entries."""
    gk = gather_block_view(k_pool_layer, block_table, block_size)
    gv = gather_block_view(v_pool_layer, block_table, block_size)
    return attend_decode(q, gk, gv, kv_len)


def attend_decode(q, cache_k, cache_v, kv_len, *, window: int = 0,
                  ring: bool = False):
    """Single-step decode attention.

    q: (B, 1, nkv, g, hd); cache_k/v: (B, S_cache, nkv, hd);
    kv_len: (B,) number of valid entries. With ``ring=True`` the cache is a
    ring buffer (sliding window) and every slot < min(len, S_cache) is valid.
    """
    B, _, nkv, g, hd = q.shape
    S = cache_k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bngh,bknh->bngk", q.squeeze(1), cache_k,
                        preferred_element_type=jnp.float32) * scale
    kj = jnp.arange(S)[None, :]
    valid = kj < jnp.minimum(kv_len, S)[:, None] if ring else kj < kv_len[:, None]
    if window and not ring:
        valid &= kj >= (kv_len[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngk,bknh->bngh", probs.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    return out[:, None].astype(cache_v.dtype)  # (B, 1, nkv, g, hd)


# ---------------------------------------------------------------------------
# Full attention block (projection + attend + output)
# ---------------------------------------------------------------------------


def attention_block(params, x, cfg: ModelConfig, positions, *,
                    cache_k=None, cache_v=None, kv_len=None,
                    mode: str = "train", window: Optional[int] = None,
                    chunk_threshold: int = 4096):
    """Self-attention for train/prefill/decode.

    Returns (out, new_k, new_v): new_k/new_v are this call's K/V entries
    (B, Sq, nkv, hd) for the cache manager to store.
    """
    window = cfg.sliding_window if window is None else window
    nkv = cfg.num_kv_heads
    B, Sq, _ = x.shape
    q, k, v = _project_qkv(params, x, x, cfg, positions)
    if mode == "project":
        # K/V (and Q) projection only — the chunked-prefill path writes the
        # cache first, then attends against it in a second call.
        return None, k, v
    qg = _expand_gqa(q, nkv)
    # NOTE: no sharding constraint here. An earlier revision constrained
    # (B, S, nkv, g, hd) with the model axis on nkv, which is not divisible
    # for GQA configs (e.g. kv=8 on a 16-way axis) and forced GSPMD into
    # replicate-then-slice remats: ~15k all-gathers per train step on
    # qwen3-8b. Propagation from the TP-sharded projections is both correct
    # and cheap — see EXPERIMENTS.md §Perf iteration 3.

    if mode == "decode":
        assert Sq == 1
        out = attend_decode(qg, cache_k, cache_v, kv_len,
                            window=window, ring=bool(window))
    elif mode == "chunk":
        # kv_len carries the per-row chunk offsets (entries before the chunk)
        out = attend_chunk_cached(qg, cache_k, cache_v, kv_len)
    elif x.shape[1] >= chunk_threshold:
        out = attend_chunked(qg, k, v, causal=True, window=window)
    else:
        out = attend_full(qg, k, v, causal=True, window=window)
    out = out.reshape(B, Sq, cfg.num_heads * cfg.resolved_head_dim)
    # row-parallel output projection: bf16 partial sums -> bf16 TP
    # all-reduce (§Perf iteration 3b)
    out = jnp.einsum("bsh,hd->bsd", out, params["w_o"],
                     preferred_element_type=out.dtype).astype(x.dtype)
    return out, k, v


def cross_attention_block(params, x, enc_kv, cfg: ModelConfig):
    """Cross-attention (whisper decoder). enc_kv: precomputed (k, v) from the
    encoder output, shapes (B, S_enc, nkv, hd)."""
    nkv = cfg.num_kv_heads
    B, Sq, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["w_q"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = q.reshape(B, Sq, cfg.num_heads, hd)
    qg = _expand_gqa(q, nkv)
    k, v = enc_kv
    out = attend_full(qg, k, v, causal=False, window=0)
    out = out.reshape(B, Sq, cfg.num_heads * hd)
    return jnp.einsum("bsh,hd->bsd", out, params["w_o"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def project_enc_kv(params, enc_out, cfg: ModelConfig):
    """Project encoder output into the decoder's cross-attention K/V."""
    B, S, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    nkv = cfg.num_kv_heads
    k = jnp.einsum("bsd,dh->bsh", enc_out, params["w_k"],
                   preferred_element_type=jnp.float32).astype(enc_out.dtype)
    v = jnp.einsum("bsd,dh->bsh", enc_out, params["w_v"],
                   preferred_element_type=jnp.float32).astype(enc_out.dtype)
    return k.reshape(B, S, nkv, hd), v.reshape(B, S, nkv, hd)
