"""Mixture-of-Experts FFN with expert parallelism.

Two execution paths with identical semantics:

* **local** (no mesh): capacity-bucketed gather/scatter dispatch on one
  device — used by smoke tests and small examples.
* **expert-parallel** (mesh): experts are sharded over the ``model`` mesh
  axes via ``shard_map``. Every model shard sees the (batch-sharded) token
  block, routes it, computes only its local experts' contribution, and the
  partial outputs are combined with a single ``psum`` over the model axes —
  the same collective cost as a Megatron TP FFN all-reduce, with no
  token all-to-all and no global sort. Load balance relies on the router
  (aux loss in training), matching standard EP practice.

Dispatch uses capacity buckets (C = ceil(T*k/E * capacity_factor)) with
deterministic cumsum slot assignment; overflowing tokens are dropped (their
combine weight is zero), the standard Switch/GShard behaviour.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.models import dist
from repro.models.layers import apply_mlp, dense_init, init_mlp, stacked_dense_init


def init_moe(key, cfg: ModelConfig, stacked: int = 0):
    assert cfg.moe is not None
    d, m = cfg.d_model, cfg.moe
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)

    def mk_expert(k, i, o):
        shape = (stacked, m.num_experts, i, o) if stacked else (m.num_experts, i, o)
        scale = 1.0 / math.sqrt(i)
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    p = {
        "router": (stacked_dense_init(ks[0], stacked, d, m.num_experts, jnp.float32)
                   if stacked else dense_init(ks[0], d, m.num_experts, jnp.float32)),
        "w_gate": mk_expert(ks[1], d, m.d_ff_expert),
        "w_up": mk_expert(ks[2], d, m.d_ff_expert),
        "w_down": mk_expert(ks[3], m.d_ff_expert, d),
    }
    if m.shared_expert_d_ff:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=m.shared_expert_d_ff, stacked=stacked)
    return p


def _route(router_w, x_flat, num_experts: int, top_k: int):
    """Router: returns (ids (T,k) int32, gates (T,k) f32, probs (T,E) f32)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return ids.astype(jnp.int32), gates, probs


def _aux_loss(probs, ids, num_experts: int):
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    T = probs.shape[0]
    assign = jax.nn.one_hot(ids[:, 0], num_experts, dtype=jnp.float32)
    f = assign.mean(0)                       # fraction routed (top-1 proxy)
    pbar = probs.mean(0)
    return num_experts * jnp.sum(f * pbar)


def _expert_compute(x_buf, w_gate, w_up, w_down, act: str):
    """Batched per-expert MLP: x_buf (E, C, d) -> (E, C, d)."""
    if act == "silu":
        g = jnp.einsum("ecd,edf->ecf", x_buf, w_gate,
                       preferred_element_type=jnp.float32).astype(x_buf.dtype)
        u = jnp.einsum("ecd,edf->ecf", x_buf, w_up,
                       preferred_element_type=jnp.float32).astype(x_buf.dtype)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x_buf.dtype) * u
    else:
        u = jnp.einsum("ecd,edf->ecf", x_buf, w_up,
                       preferred_element_type=jnp.float32)
        h = jax.nn.gelu(u, approximate=True).astype(x_buf.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_down,
                      preferred_element_type=jnp.float32).astype(x_buf.dtype)


def _dispatch_compute_combine(x_flat, ids, gates, w_gate, w_up, w_down,
                              num_experts: int, capacity: int, act: str):
    """Capacity-bucket dispatch -> per-expert MLP -> weighted combine.

    x_flat: (T, d); ids/gates: (T, k). Experts indexed 0..num_experts-1
    (callers translate to local ids for the EP path). ids < 0 mean
    "not mine / invalid" and are dropped.
    """
    T, k = ids.shape
    d = x_flat.shape[-1]
    ids_flat = ids.reshape(T * k)
    gates_flat = gates.reshape(T * k)
    valid = ids_flat >= 0
    safe_ids = jnp.where(valid, ids_flat, 0)
    # deterministic slot assignment: position among earlier tokens of the
    # same expert (cumsum of one-hot minus self)
    oh = jax.nn.one_hot(safe_ids, num_experts, dtype=jnp.int32)
    oh = oh * valid[:, None]
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - oh,
                              safe_ids[:, None], axis=1)[:, 0]
    keep = valid & (pos < capacity)
    slot = jnp.where(keep, pos, capacity)    # capacity index == out of bounds
    # scatter tokens into (E, C, d) buckets; OOB rows dropped
    x_rep = jnp.take(x_flat, jnp.arange(T * k) // k, axis=0)
    buf = jnp.zeros((num_experts, capacity, d), x_flat.dtype)
    buf = buf.at[safe_ids, slot].set(x_rep, mode="drop")
    out_buf = _expert_compute(buf, w_gate, w_up, w_down, act)
    # gather back + weighted combine over the k slots
    y = out_buf.at[safe_ids, slot].get(mode="fill", fill_value=0.0)
    y = y * (gates_flat * keep).astype(y.dtype)[:, None]
    return y.reshape(T, k, d).sum(axis=1)


def _capacity(tokens: int, k: int, num_experts: int, factor: float) -> int:
    c = int(math.ceil(tokens * k / num_experts * factor))
    return max(8, min(tokens * k, c))


def apply_moe(params, x, cfg: ModelConfig, *, train: bool = False):
    """MoE FFN. x: (B, S, d). Returns (out, aux_loss scalar f32).

    Distributed strategy (chosen by traffic napkin math, EXPERIMENTS.md
    §Perf iteration 2):
    * weights-stationary ("gather"): experts' FSDP-sharded hidden dim is
      all-gathered at use (ZeRO-3). Collective bytes ∝ expert weights.
      Right for training/prefill where tokens ≫ weights.
    * activations-moving ("scatter"): tokens are all-gathered over the FSDP
      axis, each shard computes only its f-slice, and partial outputs
      reduce-scatter back. Collective bytes ∝ 2·tokens·d. Right for decode,
      where 128 tokens would otherwise drag 2 GB of expert weights per
      layer through the interconnect.
    """
    m = cfg.moe
    B, S, d = x.shape
    ctx = dist.get_ctx()
    ep = ctx.axis_size(ctx.model_axes)
    if ctx.active and ep > 1 and m.num_experts % ep == 0:
        if _prefer_scatter(x, cfg, ctx):
            out, aux = _apply_moe_ep_scatter(params, x, cfg, ep)
        else:
            out, aux = _apply_moe_ep(params, x, cfg, ep)
    else:
        x_flat = x.reshape(B * S, d)
        ids, gates, probs = _route(params["router"], x_flat, m.num_experts, m.top_k)
        cap = _capacity(B * S, m.top_k, m.num_experts, m.capacity_factor)
        out = _dispatch_compute_combine(
            x_flat, ids, gates, params["w_gate"], params["w_up"],
            params["w_down"], m.num_experts, cap, cfg.act)
        aux = _aux_loss(probs, ids, m.num_experts)
        out = out.reshape(B, S, d)
    if "shared" in params:
        out = out + apply_mlp(params["shared"], x, cfg.act)
    return out, aux * (m.aux_loss_weight if train else 0.0)


def _fsdp_axis(ctx):
    """The FSDP storage axes for expert weights (all batch axes)."""
    baxes = tuple(ctx.batch_axes or ())
    return baxes or None


def _fsdp_size(ctx) -> int:
    axes = _fsdp_axis(ctx)
    if not axes:
        return 1
    n = 1
    for a in axes:
        n *= ctx.mesh.shape[a]
    return n


def _prefer_scatter(x, cfg: ModelConfig, ctx) -> bool:
    """Traffic model: activations-moving wins when 2·tokens·d·bytes is less
    than the per-chip FSDP expert-weight gather. REPRO_MOE_STRATEGY
    ∈ {auto, gather, scatter} overrides (used by the §Perf ablation)."""
    import os
    force = os.environ.get("REPRO_MOE_STRATEGY", "auto")
    ax = _fsdp_axis(ctx)
    if force == "gather" or ax is None:
        return False
    fsdp = _fsdp_size(ctx)
    if force == "scatter":
        return fsdp > 1 and cfg.moe.d_ff_expert % fsdp == 0
    if fsdp <= 1 or cfg.moe.d_ff_expert % fsdp != 0:
        return False
    B, S, d = x.shape
    itemsize = jnp.dtype(x.dtype).itemsize
    tokens_traffic = 2 * B * S * d * itemsize
    e_loc = cfg.moe.num_experts // max(ctx.axis_size(ctx.model_axes), 1)
    weight_traffic = (3 * e_loc * d * cfg.moe.d_ff_expert * itemsize
                      * (fsdp - 1) // fsdp)
    return tokens_traffic < weight_traffic


def _apply_moe_ep_scatter(params, x, cfg: ModelConfig, ep: int):
    """Activations-moving expert parallelism (decode-optimized).

    Tokens are all-gathered over the FSDP axis; every (fsdp, model) shard
    computes its local experts' contribution using only its LOCAL f-slice
    of the expert weights (never gathering them); partial outputs are
    reduce-scattered back over the FSDP axis and psum'd over model.
    """
    m = cfg.moe
    ctx = dist.get_ctx()
    mesh = ctx.mesh
    B, S, d = x.shape
    e_local = m.num_experts // ep
    bspec = dist.batch_spec_entry()
    mspec = dist.model_spec_entry()
    model_axes = tuple(ctx.model_axes)
    fsdp_ax = _fsdp_axis(ctx)          # tuple of all batch axes
    baxes = tuple(ctx.batch_axes or ())
    # tokens per shard after gathering over every fsdp axis: the full batch
    T_gathered = B * S
    cap = _capacity(T_gathered, m.top_k, m.num_experts, m.capacity_factor)

    def shard_fn(x_blk, router_w, w_gate, w_up, w_down):
        # gather tokens over the FSDP axis only (pod stays sharded)
        x_all = jax.lax.all_gather(x_blk, fsdp_ax, axis=0, tiled=True)
        b, s, _ = x_all.shape
        x_flat = x_all.reshape(b * s, d)
        ids, gates, probs = _route(router_w, x_flat, m.num_experts, m.top_k)
        r = 0
        for ax in model_axes:
            r = r * mesh.shape[ax] + jax.lax.axis_index(ax)
        offset = r * e_local
        local = ids - offset
        local = jnp.where((local >= 0) & (local < e_local), local, -1)
        # local f-slice expert compute; partial over the f dimension
        y = _dispatch_compute_combine(
            x_flat, local, gates, w_gate, w_up, w_down, e_local, cap,
            cfg.act)
        y = y.reshape(b, s, d)
        # sum f-slice partials + return each token to its home shard
        y = jax.lax.psum_scatter(y, fsdp_ax, scatter_dimension=0, tiled=True)
        y = jax.lax.psum(y, model_axes)          # combine expert partials
        aux = _aux_loss(probs, ids, m.num_experts)
        if baxes[:-1]:
            aux = jax.lax.pmean(aux, baxes[:-1])
        return y, aux

    out, aux = dist.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P(mspec, None, fsdp_ax), P(mspec, None, fsdp_ax),
                  P(mspec, fsdp_ax, None)),
        out_specs=(P(bspec, None, None), P()),
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return out, aux


def _apply_moe_ep(params, x, cfg: ModelConfig, ep: int):
    """Expert-parallel path: shard_map over the model axes."""
    m = cfg.moe
    ctx = dist.get_ctx()
    mesh = ctx.mesh
    B, S, d = x.shape
    e_local = m.num_experts // ep
    bspec = dist.batch_spec_entry()
    mspec = dist.model_spec_entry()
    model_axes = tuple(ctx.model_axes)
    # tokens per shard (batch may be replicated when bspec is None)
    T_local = (B // ctx.axis_size(ctx.batch_axes)) * S
    cap = _capacity(T_local, m.top_k, m.num_experts, m.capacity_factor)

    def shard_fn(x_blk, router_w, w_gate, w_up, w_down):
        b, s, _ = x_blk.shape
        x_flat = x_blk.reshape(b * s, d)
        ids, gates, probs = _route(router_w, x_flat, m.num_experts, m.top_k)
        # translate to local expert ids; foreign experts -> -1 (dropped here,
        # computed by the shard that owns them)
        r = 0
        for ax in model_axes:
            r = r * mesh.shape[ax] + jax.lax.axis_index(ax)
        offset = r * e_local
        local = ids - offset
        local = jnp.where((local >= 0) & (local < e_local), local, -1)
        y = _dispatch_compute_combine(
            x_flat, local, gates, w_gate, w_up, w_down, e_local, cap, cfg.act)
        y = jax.lax.psum(y, model_axes)      # combine expert partials
        aux = _aux_loss(probs, ids, m.num_experts)
        if ctx.batch_axes:
            aux = jax.lax.pmean(aux, tuple(ctx.batch_axes))
        return y.reshape(b, s, d), aux

    out, aux = dist.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P(mspec, None, None), P(mspec, None, None),
                  P(mspec, None, None)),
        out_specs=(P(bspec, None, None), P()),
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return out, aux
