"""Shared neural-net building blocks: norms, RoPE, MLPs, embeddings.

Parameters are plain dict pytrees of jnp arrays; ``init_*`` functions build
them, ``apply_*``/lowercase functions consume them. All matmuls accumulate in
float32 (``preferred_element_type``) when params are bf16.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def stacked_dense_init(key, num: int, in_dim: int, out_dim: int, dtype,
                       scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (num, in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def matmul(x, w):
    """x @ w with f32 accumulation."""
    return jnp.einsum("...d,df->...f", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    """Inverse frequencies for rotary embeddings (half-dim)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """Rotate pairs. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    if theta <= 0:
        return x
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)          # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU / squared-ReLU)
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None, stacked: int = 0):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    mk = (lambda k, i, o: stacked_dense_init(k, stacked, i, o, dtype)) if stacked \
        else (lambda k, i, o: dense_init(k, i, o, dtype))
    if cfg.act == "silu":
        return {"w_gate": mk(ks[0], d, f), "w_up": mk(ks[1], d, f),
                "w_down": mk(ks[2], f, d)}
    return {"w_up": mk(ks[1], d, f), "w_down": mk(ks[2], f, d)}


def apply_mlp(params, x, act: str):
    if act == "silu":
        gate = matmul(x, params["w_gate"])
        up = matmul(x, params["w_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif act == "gelu":
        h = jax.nn.gelu(matmul(x, params["w_up"]).astype(jnp.float32),
                        approximate=True).astype(x.dtype)
    elif act == "relu_sq":
        h = jnp.square(jax.nn.relu(matmul(x, params["w_up"])))
    else:
        raise ValueError(f"unknown act {act!r}")
    # row-parallel projection: emit the activation dtype so the TP partial
    # sum is all-reduced in bf16, not f32 (halves the dominant train
    # collective; the MXU accumulates in f32 internally regardless) —
    # EXPERIMENTS.md §Perf iteration 3b
    return jnp.einsum("...f,fd->...d", h, params["w_down"],
                      preferred_element_type=h.dtype)


# ---------------------------------------------------------------------------
# embeddings / LM head
# ---------------------------------------------------------------------------


def init_embeddings(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    p = {"tok": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model), jnp.float32)
                 * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, cfg.d_model, cfg.vocab_size, dtype)
    return p


def embed(params, tokens):
    return jnp.take(params["tok"], tokens, axis=0)


def lm_head(params, x):
    w = params.get("head")
    if w is None:
        w = params["tok"].T
    return jnp.einsum("...d,dv->...v", x, w,
                      preferred_element_type=jnp.float32)
