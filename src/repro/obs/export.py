"""Chrome trace-event / Perfetto JSON export of the flight recorder
(DESIGN.md §17).

The format is the Trace Event Format's JSON-array flavor — loadable by
``chrome://tracing`` and https://ui.perfetto.dev — so the paper's timing
claims become visually inspectable timelines: per-(stage, microbatch)
spans stack per stage track, the pool workers' ``host_sample`` spans sit
on their own thread tracks overlapping the next forward (Eq. 4's
overlap), and ``pool_stall`` spans show exactly when the pool missed the
pipeline's slack.

Mapping: each (process_name, tracer) source becomes one ``pid``; each
distinct span ``track`` within it becomes a ``tid`` with a
``thread_name`` metadata event; spans are ``ph="X"`` complete events
with microsecond ``ts``/``dur``, instants are ``ph="i"`` with thread
scope. Every event carries the ``ph`` / ``ts`` / ``pid`` / ``tid`` keys
the viewers require. Sources must share one clock (``perf_counter`` —
the repo-wide discipline) since the viewer merges on raw timestamps.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Sequence, Tuple, Union

from repro.obs.tracer import SpanEvent, StepTracer

Source = Tuple[str, Union[StepTracer, Sequence[SpanEvent]]]


def chrome_trace_events(sources: Iterable[Source]) -> List[dict]:
    """Flatten (process_name, tracer-or-events) sources into Chrome
    trace-event dicts (metadata first, then events in time order)."""
    out: List[dict] = []
    # source order is the callers' (gateway first, then replicas): each
    # becomes one pid, so the viewer groups rows per process in that order
    for pid, (pname, src) in enumerate(list(sources), start=1):
        evs = src.events() if isinstance(src, StepTracer) else list(src)
        evs = sorted(evs, key=lambda e: (e.ts, e.dur))
        out.append({"ph": "M", "name": "process_name", "pid": pid,
                    "tid": 0, "ts": 0, "args": {"name": pname}})
        tids: Dict[str, int] = {}
        body: List[dict] = []
        for e in evs:
            tid = tids.get(e.track)
            if tid is None:
                tid = tids[e.track] = len(tids) + 1
            rec = {"name": e.name, "cat": e.kind, "ph": e.ph,
                   "ts": round(e.ts * 1e6, 3), "pid": pid, "tid": tid,
                   "args": dict(e.args)}
            if e.ph == "X":
                rec["dur"] = round(e.dur * 1e6, 3)
            else:
                rec["s"] = "t"      # thread-scoped instant
            body.append(rec)
        for track, tid in tids.items():
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "ts": 0, "args": {"name": track}})
        out.extend(body)
    return out


def chrome_trace(sources: Iterable[Source]) -> dict:
    """The JSON-object flavor: ``{"traceEvents": [...]}`` plus the
    display unit hint Perfetto honors."""
    return {"traceEvents": chrome_trace_events(sources),
            "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, sources: Iterable[Source]) -> int:
    """Serialize :func:`chrome_trace` to ``path``; returns the number of
    trace events written (metadata included)."""
    doc = chrome_trace(sources)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f)
    return len(doc["traceEvents"])


__all__ = ["chrome_trace_events", "chrome_trace", "write_chrome_trace"]
