"""Per-engine telemetry bundle: one tracer + one metrics registry
(DESIGN.md §17).

Both engines own a :class:`Telemetry`; the gateway aggregates them —
``GET /metrics`` renders every replica engine's registry with an
injected ``replica`` label next to the gateway's own, and
``GET /v1/trace`` merges the tracers into one Chrome trace.

Defaults encode the overhead contract: **metrics on** (a few locked
float updates per committed step — invisible next to a forward) and
**tracing off** (the flight recorder is a debugging instrument; enable
it per run with ``serve.py --trace-out`` or per engine by passing an
enabled :class:`~repro.obs.tracer.StepTracer`).

:class:`EngineMetrics` is the single definition of the engines' metric
families, so the single-stage and pipeline engines cannot drift apart in
naming — the decomposition the paper argues with (pool stall, sampler
vs transfer time, queue depth/delay, bubble fraction) appears under the
same names for both.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.records import StepRecord
from repro.obs.tracer import StepTracer


class Telemetry:
    """One engine's observability handle (tracer + metrics registry)."""

    def __init__(self, tracer: Optional[StepTracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.tracer = tracer if tracer is not None else \
            StepTracer(capacity=16384, enabled=False)
        self.metrics = metrics if metrics is not None else MetricsRegistry()


class EngineMetrics:
    """The engines' shared instrument set over a registry.

    ``observe_step`` consumes the same validated :class:`StepRecord`
    stream the controller and benchmarks read — the record IS the
    metrics update, so /metrics can never disagree with ``stats_log``.
    """

    def __init__(self, registry: MetricsRegistry):
        m = registry
        self.steps = m.counter(
            "engine_steps_total", "committed engine iterations")
        self.tokens = m.counter(
            "engine_tokens_committed_total",
            "tokens committed to request state")
        self.queue_depth = m.gauge(
            "engine_queue_depth", "requests waiting for admission")
        self.batch = m.gauge(
            "engine_batch_occupancy", "active rows in the last commit")
        self.mode_host = m.gauge(
            "engine_sampler_mode_host",
            "decision-plane placement: 1 = host sampler pool, 0 = device")
        self.pool_workers = m.gauge(
            "engine_pool_workers", "host sampler pool worker count")
        self.stall = m.histogram(
            "engine_pool_stall_ms",
            "commit block on the sampler-pool ticket (host mode)")
        self.sampler = m.histogram(
            "engine_sampler_ms",
            "pool CPU sampling time per step, fetch excluded (max shard)")
        self.transfer = m.histogram(
            "engine_transfer_ms",
            "pool device_get wait per step (in-flight compute + D2H)")
        self.queue_delay = m.histogram(
            "engine_queue_delay_ms",
            "oldest waiting request's queueing delay at commit")
        self.bubble = m.gauge(
            "pipeline_bubble_frac",
            "Eq. 4 bubble fraction of the last full pipeline cycle "
            "(0 until a pipeline engine reports one)")
        self.decisions = m.counter(
            "controller_decisions_total",
            "decision-plane controller actions applied (any knob)")
        # prefill/decode disaggregation (§18): migration flow + the
        # router-debuggability gauges behind GET /v1/stats and /metrics
        self.migrations_out = m.counter(
            "engine_migrations_out_total",
            "requests exported with their KV (disaggregation, §18)")
        self.migrations_in = m.counter(
            "engine_migrations_in_total",
            "requests imported with carried KV (disaggregation, §18)")
        self.free_blocks = m.gauge(
            "engine_free_kv_blocks",
            "free blocks in the paged KV pool (-1 = contiguous cache)")
        self.pending_imports = m.gauge(
            "engine_pending_imports",
            "admitted-but-not-installed carried-KV requests")

    def observe_step(self, rec: StepRecord) -> None:
        """Fold one committed step's record into the instruments."""
        self.steps.inc()
        self.tokens.inc(rec.batch)
        self.batch.set(rec.batch)
        if rec.queue_depth is not None:
            self.queue_depth.set(rec.queue_depth)
        if rec.queue_delay_ms is not None:
            self.queue_delay.observe(rec.queue_delay_ms)   # NaN dropped
        if rec.stall_ms is not None:
            self.stall.observe(rec.stall_ms)
        if rec.sampler_ms is not None:
            self.sampler.observe(rec.sampler_ms)
        if rec.transfer_ms is not None:
            self.transfer.observe(rec.transfer_ms)
        if rec.bubble_frac is not None and math.isfinite(rec.bubble_frac):
            self.bubble.set(rec.bubble_frac)


__all__ = ["Telemetry", "EngineMetrics"]
