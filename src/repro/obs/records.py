"""Typed step records — ONE schema for the engines' stat streams
(DESIGN.md §17).

Before the telemetry plane, both engines appended free-form dicts to
``stats_log`` and the pipeline kept a second private spelling in
``cycle_log``; the controller, serve.py's report, and the benchmarks each
re-derived which keys might be present. :class:`StepRecord` replaces the
dicts with a validated dataclass, and :class:`CycleRecord` types the
pipeline's per-cycle row. Both keep **mapping-style duck typing**
(``"stall_ms" in rec`` / ``rec["stall_ms"]`` / ``rec.get``) with the
dict convention the old consumers relied on: a field is *present* iff it
is set and not ``None`` — so ``"stall_ms" not in rec`` still reads "this
was a device-mode step" exactly as it did with the dicts.

Optionality encodes the decision-plane placement: ``stall_ms`` /
``sampler_ms`` / ``transfer_ms`` exist only for host-sampled steps
(§13's pool decomposition), ``bubble_frac`` only for pipeline commits,
and ``hot_size`` / ``samplers`` / ``sampler_mode`` only on steps where a
controller acted (§15). ``accept_rate`` / ``alpha_mean`` /
``fallback_rate`` may be NaN (all-inactive microbatches pool to NaN
stats) — NaN means "no sample", never "zero".
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, fields
from typing import Any, Dict, Iterator, List, Optional

_NAN = float("nan")


class RecordMapping:
    """Mapping-style duck typing over dataclass fields: presence ==
    "set and not None", matching the optional-key convention of the
    free-form dicts these records replaced."""

    __slots__ = ()

    def __contains__(self, name: str) -> bool:
        try:
            return getattr(self, name) is not None
        except AttributeError:
            return False

    def __getitem__(self, name: str) -> Any:
        if name not in self:
            raise KeyError(name)
        return getattr(self, name)

    def get(self, name: str, default: Any = None) -> Any:
        return self[name] if name in self else default

    def keys(self) -> Iterator[str]:
        return iter(f.name for f in fields(self) if f.name in self)

    def as_dict(self) -> Dict[str, Any]:
        """Only the fields that are present — exactly the dict the old
        code would have built."""
        return {k: getattr(self, k) for k in self.keys()}


def _check_ms(name: str, v: Optional[float],
              nan_ok: bool = False) -> Optional[float]:
    if v is None:
        return None
    v = float(v)
    if math.isnan(v):
        if nan_ok:
            return v
        raise ValueError(f"{name} must not be NaN")
    if not math.isfinite(v) or v < 0.0:
        raise ValueError(f"{name} must be a finite non-negative "
                         f"duration in ms, got {v!r}")
    return v


@dataclass
class StepRecord(RecordMapping):
    """One committed engine iteration's observability stats — the
    validated stream behind ``Engine.stats_log`` /
    ``PipelineEngine.stats_log``, consumed unchanged by
    :meth:`repro.core.autotune.DecisionPlaneController.observe_record`,
    serve.py's report, and the latency benchmarks."""

    step: int                              # dispatch step / pipeline cycle
    batch: int                             # active rows committed
    accept_rate: float = _NAN              # NaN = no active rows sampled
    alpha_mean: float = _NAN
    fallback_rate: float = _NAN
    # host-sampled steps only (§13 pool decomposition)
    stall_ms: Optional[float] = None       # block on the pool ticket
    sampler_ms: Optional[float] = None     # worker CPU sampling (max shard)
    transfer_ms: Optional[float] = None    # worker device_get wait
    # queue state at commit time (always stamped by the engines)
    queue_depth: Optional[float] = None
    queue_delay_ms: Optional[float] = None  # NaN when arrivals lack stamps
    # pipeline commits only
    bubble_frac: Optional[float] = None     # NaN during fill/drain ramp
    # controller actions landing on this step (§15)
    hot_size: Optional[int] = None
    samplers: Optional[int] = None
    sampler_mode: Optional[str] = None

    def __post_init__(self) -> None:
        self.step = int(self.step)
        self.batch = int(self.batch)
        if self.step < 0 or self.batch < 0:
            raise ValueError(
                f"step/batch must be >= 0, got {self.step}/{self.batch}")
        self.accept_rate = float(self.accept_rate)
        self.alpha_mean = float(self.alpha_mean)
        self.fallback_rate = float(self.fallback_rate)
        self.stall_ms = _check_ms("stall_ms", self.stall_ms)
        self.sampler_ms = _check_ms("sampler_ms", self.sampler_ms)
        self.transfer_ms = _check_ms("transfer_ms", self.transfer_ms)
        if self.queue_depth is not None:
            self.queue_depth = float(self.queue_depth)
            if not (self.queue_depth >= 0.0):
                raise ValueError(
                    f"queue_depth must be >= 0, got {self.queue_depth!r}")
        self.queue_delay_ms = _check_ms("queue_delay_ms",
                                        self.queue_delay_ms, nan_ok=True)
        if self.bubble_frac is not None:
            self.bubble_frac = float(self.bubble_frac)
        if self.hot_size is not None:
            self.hot_size = int(self.hot_size)
        if self.samplers is not None:
            self.samplers = int(self.samplers)
        if self.sampler_mode is not None and \
                self.sampler_mode not in ("device", "host"):
            raise ValueError(
                f"sampler_mode must be 'device' or 'host' (canonical "
                f"client spelling), got {self.sampler_mode!r}")

    @property
    def is_host(self) -> bool:
        """Whether this step's decision ran on the host sampler pool."""
        return self.stall_ms is not None

    def controller_streams(self) -> Dict[str, float]:
        """The §15 controller's observation kwargs — missing fields become
        NaN, which the controller drops per stream without stalling its
        adjust clock (``CONTROLLER_STREAMS`` in repro.core.autotune)."""
        opt = lambda v: _NAN if v is None else float(v)
        return {
            "queue_depth": opt(self.queue_depth),
            "queue_delay_ms": opt(self.queue_delay_ms),
            "batch": float(self.batch),
            "stall_ms": opt(self.stall_ms),
            "sampler_ms": opt(self.sampler_ms),
            "transfer_ms": opt(self.transfer_ms),
            "bubble_frac": opt(self.bubble_frac),
            "alpha_mean": self.alpha_mean,
        }


@dataclass
class CycleRecord(RecordMapping):
    """One pipeline cycle's timing row (``PipelineEngine.cycle_log``):
    per-stage honest busy time plus the sampling-path costs the Eq. 4
    bubble accounting needs. ``busy[s]`` is ``None`` for a stage that
    served no microbatch this cycle (fill/drain ramp)."""

    cycle: int
    busy: List[Optional[float]] = field(default_factory=list)  # seconds
    stall: float = 0.0              # commit block on the pool ticket (s)
    sample: float = 0.0             # synchronous last-stage draw (s, Eq. 4)
    sampler: Optional[float] = None    # pool CPU sampling (s)
    transfer: Optional[float] = None   # pool device_get wait (s)

    def __post_init__(self) -> None:
        self.cycle = int(self.cycle)
        if self.cycle < 0:
            raise ValueError(f"cycle must be >= 0, got {self.cycle}")

    @property
    def full(self) -> bool:
        """Every stage served a microbatch — a steady-state cycle."""
        return all(b is not None for b in self.busy)


__all__ = ["StepRecord", "CycleRecord", "RecordMapping"]
