"""Span-based step tracer + bounded flight recorder (DESIGN.md §17).

One tracer per engine (plus one on the gateway) records *typed spans* on
a single monotonic clock — ``time.perf_counter``, the clock every other
timestamp in the repo (request arrivals, stage busy times, pool
fetch/sample splits) is already taken on — into a ``deque(maxlen=N)``
ring buffer: a flight recorder that always holds the most recent window
and never grows, so it can stay attached to a long-lived gateway replica.

Span taxonomy (:data:`SPAN_KINDS`): the timing decomposition the paper's
argument is made of, one kind per seam —

    ``prefill``       admission prefill program (both engines)
    ``forward``       decode forward, dispatch → host materialization
    ``stage``         one (stage, microbatch) pipeline forward (honest,
                      ``block_until_ready``)
    ``d2h_transfer``  a pool worker's ``device_get`` wait (in-flight
                      compute + D2H copy)
    ``host_sample``   a pool worker's CPU sampling, fetch excluded
    ``pool_stall``    the engine blocking on a sampler-pool ticket —
                      the paper's "pool too slow for the slack"
    ``commit``        scheduler.commit of a step's tokens
    ``queue_wait``    a request's arrival → admission wait
    ``decision``      a controller action (instant event, §15)
    ``request``       one request's wire-level life on the gateway
    ``kv_migrate``    one migration's export gather or import scatter
                      (prefill/decode disaggregation, §18)
    ``handoff_wait``  export stamp → import install of one migrating
                      request — the KV's time in flight between engines

Threading: the engine thread, every pool worker thread, and the gateway
loop record into the same tracer. ``deque.append`` is atomic under the
GIL, so recording needs no lock; each event carries a ``track`` (default:
the recording thread's name) that becomes its own timeline row in the
Chrome-trace export — overlap between the pool workers' ``host_sample``
spans and the engine track's next ``forward``/``stage`` span is the
paper's Eq. 4 claim, made visually inspectable.

Overhead discipline: a disabled tracer's :meth:`StepTracer.span` returns
one shared no-op context manager (no allocation) and ``add``/``instant``
return immediately; instrumentation sites that build f-string names
guard on :attr:`StepTracer.enabled` so a production engine pays a single
attribute check per site.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Iterable, List, NamedTuple, Optional, Tuple

#: the typed span taxonomy (DESIGN.md §17) — unknown kinds are rejected
#: at record time so a typo'd instrumentation site fails loudly in tests,
#: not silently as an un-filterable category.
SPAN_KINDS = frozenset({
    "prefill", "forward", "stage", "d2h_transfer", "host_sample",
    "pool_stall", "commit", "queue_wait", "decision", "request",
    "kv_migrate", "handoff_wait",
})


class SpanEvent(NamedTuple):
    """One recorded span (``ph="X"``) or instant event (``ph="i"``).
    Timestamps are ``time.perf_counter`` seconds; ``args`` is a sorted
    tuple of (key, value) pairs so events stay hashable/immutable."""

    kind: str                       # SPAN_KINDS entry (Chrome trace `cat`)
    name: str                       # display name (falls back to kind)
    ph: str                         # "X" complete | "i" instant
    ts: float                       # start, perf_counter seconds
    dur: float                      # seconds (0.0 for instants)
    track: str                      # timeline row (thread / stage / role)
    args: Tuple[Tuple[str, object], ...] = ()

    @property
    def end(self) -> float:
        return self.ts + self.dur


class _NullSpan:
    """Shared no-op context manager — the disabled tracer's entire cost."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """Live span context manager: stamps entry/exit on the tracer's clock
    and records on exit (so nested spans land after their parents start
    and strictly inside them — one clock, no cross-clock skew)."""

    __slots__ = ("_tr", "_kind", "_name", "_track", "_args", "_t0")

    def __init__(self, tracer: "StepTracer", kind: str, name: Optional[str],
                 track: Optional[str], args: dict):
        self._tr = tracer
        self._kind = kind
        self._name = name
        self._track = track
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = self._tr.clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tr
        tr.add(self._kind, self._t0, tr.clock(), name=self._name,
               track=self._track, **self._args)
        return False


class StepTracer:
    """Flight recorder of :class:`SpanEvent` items in a bounded ring
    buffer (``capacity`` most recent events; oldest evicted first).

    ``enabled=False`` (the engines' default) makes every record path a
    near-free early return; flip it on per run (``serve.py --trace-out``)
    or per instance (the obs test suite). ``clock`` is injectable for
    tests but must be shared by every tracer whose events are exported
    together — the Chrome trace merges sources on raw timestamps.
    """

    def __init__(self, capacity: int = 16384, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.clock = clock
        self._enabled = bool(enabled)
        self._buf: deque = deque(maxlen=self.capacity)

    # -- switches -------------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    # -- recording ------------------------------------------------------------
    def span(self, kind: str, name: Optional[str] = None,
             track: Optional[str] = None, **args):
        """Context manager timing its body; disabled tracers return the
        shared :data:`NULL_SPAN` (zero allocation)."""
        if not self._enabled:
            return NULL_SPAN
        return _Span(self, kind, name, track, args)

    def add(self, kind: str, t0: float, t1: float,
            name: Optional[str] = None, track: Optional[str] = None,
            **args) -> None:
        """Record a span from explicit clock stamps — the path for sites
        that already measured (pool workers' fetch/sample split, stage
        busy times, request arrival→admission waits)."""
        if not self._enabled:
            return
        self._record(kind, name, "X", t0, max(0.0, t1 - t0), track, args)

    def instant(self, kind: str, name: Optional[str] = None,
                track: Optional[str] = None, **args) -> None:
        """Record a zero-duration marker (controller decisions)."""
        if not self._enabled:
            return
        self._record(kind, name, "i", self.clock(), 0.0, track, args)

    def _record(self, kind: str, name: Optional[str], ph: str, ts: float,
                dur: float, track: Optional[str], args: dict) -> None:
        if kind not in SPAN_KINDS:
            raise ValueError(f"unknown span kind {kind!r}; taxonomy: "
                             f"{sorted(SPAN_KINDS)} (DESIGN.md §17)")
        if track is None:
            track = threading.current_thread().name
        # deque.append with maxlen is atomic under the GIL: engine thread,
        # pool workers, and the gateway loop record without a lock
        self._buf.append(SpanEvent(
            kind=kind, name=name or kind, ph=ph, ts=float(ts),
            dur=float(dur), track=track,
            args=tuple(sorted(args.items()))))

    # -- reading --------------------------------------------------------------
    def events(self) -> List[SpanEvent]:
        """Snapshot of the ring buffer, oldest first."""
        return list(self._buf)

    def clear(self) -> None:
        self._buf.clear()

    def __len__(self) -> int:
        return len(self._buf)


#: shared disabled tracer — the default wiring for components that accept
#: a tracer but were constructed without one (e.g. a bare HostSamplerPool).
#: Never enable it: every un-wired component in the process shares it.
NULL_TRACER = StepTracer(capacity=1, enabled=False)


def merge_events(sources: Iterable[StepTracer]) -> List[SpanEvent]:
    """Events from several tracers on one clock, sorted by start time."""
    out: List[SpanEvent] = []
    for tr in sources:
        out.extend(tr.events())
    out.sort(key=lambda e: e.ts)
    return out


__all__ = ["SPAN_KINDS", "SpanEvent", "StepTracer", "NULL_TRACER",
           "NULL_SPAN", "merge_events"]
