"""Stdlib metrics registry with Prometheus text exposition (DESIGN.md §17).

Counters, gauges, and fixed-bucket histograms — everything the gateway's
``GET /metrics`` endpoint serves — with no dependency beyond the standard
library (the same constraint as the gateway itself: one process, stdlib
only). Instruments are get-or-create by ``(name, labels)`` so hot paths
may re-ask the registry for a labeled series without allocation churn;
each instrument carries its own lock (engine thread, pool workers, and
the gateway loop all write).

The exposition format is the Prometheus text format 0.0.4: ``# HELP`` /
``# TYPE`` per family, ``name{label="value"} v`` samples, histograms as
cumulative ``_bucket{le=...}`` plus ``_sum`` / ``_count``.
:func:`render_registries` merges several registries into one page with
per-registry injected labels — the gateway renders its own registry plus
every replica engine's registry tagged ``replica="..."``, each family's
HELP/TYPE emitted once.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: default latency buckets (milliseconds) — spans the sub-ms pool
#: decomposition up through multi-second queueing tails.
DEFAULT_MS_BUCKETS: Tuple[float, ...] = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
    500.0, 1000.0, 2500.0, 5000.0)

LabelPairs = Tuple[Tuple[str, str], ...]


def _escape(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"') \
                .replace("\n", "\\n")


def _fmt_labels(labels: LabelPairs) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in labels)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


class Counter:
    """Monotonically increasing sample."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up, got inc({amount!r})")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def samples(self, name: str, labels: LabelPairs) -> List[str]:
        return [f"{name}{_fmt_labels(labels)} {_fmt_value(self._value)}"]


class Gauge:
    """Settable sample (queue depth, pool width, placement flag)."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        return self._value

    def samples(self, name: str, labels: LabelPairs) -> List[str]:
        return [f"{name}{_fmt_labels(labels)} {_fmt_value(self._value)}"]


class Histogram:
    """Fixed-bucket histogram (Prometheus cumulative exposition).

    ``buckets`` are the finite upper bounds; a ``+Inf`` bucket is
    implicit. Non-finite observations are dropped — NaN stats ("no
    sample", §13) must not poison ``_sum``.
    """

    __slots__ = ("_lock", "uppers", "_counts", "_sum", "_count")

    def __init__(self, buckets: Sequence[float] = DEFAULT_MS_BUCKETS):
        uppers = tuple(sorted(float(b) for b in buckets))
        if not uppers or any(not math.isfinite(b) for b in uppers):
            raise ValueError(f"buckets must be finite and non-empty, "
                             f"got {buckets!r}")
        if len(set(uppers)) != len(uppers):
            raise ValueError(f"duplicate bucket bounds: {buckets!r}")
        self.uppers = uppers
        self._lock = threading.Lock()
        self._counts = [0] * (len(uppers) + 1)      # + the +Inf bucket
        self._sum = 0.0
        self._count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        if not math.isfinite(v):
            return
        i = 0
        for i, ub in enumerate(self.uppers):
            if v <= ub:
                break
        else:
            i = len(self.uppers)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def samples(self, name: str, labels: LabelPairs) -> List[str]:
        out: List[str] = []
        cum = 0
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
        for ub, c in zip(self.uppers, counts):
            cum += c
            le = (("le", format(ub, "g")),)
            out.append(f"{name}_bucket{_fmt_labels(labels + le)} {cum}")
        out.append(f"{name}_bucket{_fmt_labels(labels + (('le', '+Inf'),))} "
                   f"{total}")
        out.append(f"{name}_sum{_fmt_labels(labels)} {_fmt_value(s)}")
        out.append(f"{name}_count{_fmt_labels(labels)} {total}")
        return out


_TYPES = {Counter: "counter", Gauge: "gauge", Histogram: "histogram"}


class MetricsRegistry:
    """Get-or-create instrument store keyed by ``(name, labels)``.

    One family (shared name) may carry many label sets but exactly one
    instrument type and help string — re-registering with a conflicting
    type fails loudly at the call site, not at scrape time.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._families: Dict[str, Tuple[type, str]] = {}
        self._series: Dict[Tuple[str, LabelPairs], object] = {}

    def _get(self, cls: type, name: str, help_: str, labels: Dict[str, str],
             factory) -> object:
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        pairs: LabelPairs = tuple(sorted(
            (str(k), str(v)) for k, v in labels.items()))
        for k, _ in pairs:
            if not _LABEL_RE.match(k):
                raise ValueError(f"bad label name {k!r} on {name!r}")
        key = (name, pairs)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                self._families[name] = (cls, help_)
            elif fam[0] is not cls:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{_TYPES[fam[0]]}, asked for {_TYPES[cls]}")
            inst = self._series.get(key)
            if inst is None:
                inst = self._series[key] = factory()
            return inst

    def counter(self, name: str, help_: str = "", **labels) -> Counter:
        return self._get(Counter, name, help_, labels, Counter)

    def gauge(self, name: str, help_: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help_, labels, Gauge)

    def histogram(self, name: str, help_: str = "",
                  buckets: Sequence[float] = DEFAULT_MS_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, help_, labels,
                         lambda: Histogram(buckets))

    def collect(self) -> Dict[str, Tuple[str, str,
                                         List[Tuple[LabelPairs, object]]]]:
        """``{family: (type, help, [(labels, instrument), ...])}`` with
        label sets in sorted order (stable exposition)."""
        with self._lock:
            fams = dict(self._families)
            series = dict(self._series)
        out: Dict[str, Tuple[str, str, List[Tuple[LabelPairs, object]]]] = {}
        for name, (cls, help_) in sorted(fams.items()):
            rows = sorted(((pairs, inst) for (n, pairs), inst
                           in series.items() if n == name),
                          key=lambda kv: kv[0])
            out[name] = (_TYPES[cls], help_, rows)
        return out

    def render(self, extra_labels: Optional[Dict[str, str]] = None) -> str:
        return render_registries([(extra_labels or {}, self)])


def render_registries(
        registries: Iterable[Tuple[Dict[str, str], MetricsRegistry]]) -> str:
    """Prometheus text page over several registries, each with injected
    labels; families sharing a name across registries are merged under
    one HELP/TYPE header (they must agree on the instrument type)."""
    merged: Dict[str, Tuple[str, str, List[str]]] = {}
    for extra, reg in registries:
        inject: LabelPairs = tuple(sorted(
            (str(k), str(v)) for k, v in (extra or {}).items()))
        for name, (typ, help_, rows) in reg.collect().items():
            if name in merged and merged[name][0] != typ:
                raise ValueError(
                    f"metric {name!r} is a {merged[name][0]} in one "
                    f"registry and a {typ} in another")
            lines = merged.setdefault(name, (typ, help_, []))[2]
            for pairs, inst in rows:
                lines.extend(inst.samples(name, inject + pairs))
    out: List[str] = []
    for name in sorted(merged):
        typ, help_, lines = merged[name]
        if help_:
            out.append(f"# HELP {name} {_escape(help_)}")
        out.append(f"# TYPE {name} {typ}")
        out.extend(lines)
    return "\n".join(out) + "\n" if out else ""


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "render_registries", "DEFAULT_MS_BUCKETS"]
