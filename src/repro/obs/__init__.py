"""Unified telemetry plane (DESIGN.md §17): typed step records, a
span-based flight recorder on one clock, Chrome-trace/Perfetto export,
and a stdlib metrics registry with Prometheus text exposition —
cross-cutting over both engines, the host sampler pool, the adaptive
controller, and the gateway."""
from repro.obs.export import (chrome_trace, chrome_trace_events,
                              write_chrome_trace)
from repro.obs.metrics import (DEFAULT_MS_BUCKETS, Counter, Gauge,
                               Histogram, MetricsRegistry,
                               render_registries)
from repro.obs.records import CycleRecord, RecordMapping, StepRecord
from repro.obs.telemetry import EngineMetrics, Telemetry
from repro.obs.tracer import (NULL_SPAN, NULL_TRACER, SPAN_KINDS,
                              SpanEvent, StepTracer, merge_events)

__all__ = [
    "StepRecord", "CycleRecord", "RecordMapping",
    "StepTracer", "SpanEvent", "SPAN_KINDS", "NULL_TRACER", "NULL_SPAN",
    "merge_events",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "render_registries", "DEFAULT_MS_BUCKETS",
    "chrome_trace", "chrome_trace_events", "write_chrome_trace",
    "Telemetry", "EngineMetrics",
]
